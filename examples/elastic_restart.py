"""Fault-tolerance demo: train, kill, lose devices, re-plan, resume.

    PYTHONPATH=src python examples/elastic_restart.py

1. trains for 40 steps with checkpoints,
2. simulates a crash (process state discarded),
3. simulates the loss of 2 of 16 devices, re-plans the mesh,
4. restores the (topology-independent) checkpoint and finishes training —
   verifying the loss continues to decrease across the restart.
"""
import dataclasses
import shutil

from repro.configs import (OptimizerConfig, ParallelPlan, RecomputeConfig,
                           ShapeConfig, TrainConfig, get_reduced)
from repro.ft import MeshRequirements, simulate_failures
from repro.launch.train import train

CKPT = "/tmp/repro_elastic_demo"


def build_tc(steps):
    model = dataclasses.replace(
        get_reduced("tinyllama-1.1b"), name="llama-elastic", num_layers=2,
        d_model=128, num_heads=4, num_kv_heads=2, d_ff=352,
        vocab_size=1024)
    return TrainConfig(
        model=model, shape=ShapeConfig("train_64", 64, 8, "train"),
        plan=ParallelPlan(microbatch_size=8, num_chunks=2,
                          recompute=RecomputeConfig(mode="chronos")),
        optimizer=OptimizerConfig(lr=1e-3, warmup_steps=5,
                                  total_steps=steps, schedule="constant"),
        log_every=10, checkpoint_every=20, checkpoint_dir=CKPT)


def main():
    shutil.rmtree(CKPT, ignore_errors=True)

    print("=== phase 1: train 40 steps, then 'crash' ===")
    out1 = train(build_tc(80), steps=40)
    loss_at_crash = out1["final_loss"]

    print("=== phase 2: 2 of 16 devices fail -> re-plan ===")
    req = MeshRequirements(tp_divides=4, global_batch=64)
    decision = simulate_failures(16, failed=[3, 11], req=req)
    print(f"elastic decision: dp={decision.dp} tp={decision.tp} "
          f"using {decision.devices_used}/14 devices, "
          f"per-replica batch {decision.per_replica_batch}")

    print("=== phase 3: restore + resume on the new plan ===")
    out2 = train(build_tc(80), steps=80)   # restores from CKPT
    print(f"loss at crash: {loss_at_crash:.4f}; "
          f"after resume: {out2['final_loss']:.4f}")
    assert out2["final_loss"] < loss_at_crash + 0.05
    print("elastic restart OK: training continued from the checkpoint")


if __name__ == "__main__":
    main()
