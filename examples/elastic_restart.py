"""Elastic fault-tolerant pipeline training demo: kill, re-plan, resume.

    PYTHONPATH=src python examples/elastic_restart.py          # 16 devices
    PYTHONPATH=src python examples/elastic_restart.py --dry    # 2 devices

The full run drives ``repro.ft.elastic_pipeline.train_elastic`` over 16
forced-host devices with a deterministic fault schedule
(``repro.ft.inject``):

1. trains a chronos pipeline at P=16 with periodic checkpoints,
2. a stage dies mid-run -> the health check surfaces a DeviceLossError,
   the mesh re-plans at P=15, the topology-independent checkpoint
   restores and the stacked parameter blocks + optimizer moments
   live-migrate onto the new ``StageLayout`` (remap_blocks_elastic),
3. a hung collective trips the (fake-clock) watchdog -> P=14,
4. the lost devices rejoin -> preemptible warm restart scales back to 16,
5. the run finishes step-count-exact: every step 0..N-1 has exactly one
   loss, and the trajectory keeps decreasing across all four topologies.

``--dry`` shrinks everything (2 devices, P=2 -> 1 -> 2, a handful of
steps) so the fast test tier can execute the demo end-to-end.
"""
import dataclasses
import os
import shutil
import sys
import tempfile

DRY = "--dry" in sys.argv
N_DEV = 2 if DRY else 16
os.environ.setdefault("XLA_FLAGS",
                      f"--xla_force_host_platform_device_count={N_DEV}")

from repro.configs import (OptimizerConfig, ParallelPlan,  # noqa: E402
                           ShapeConfig, TrainConfig, get_reduced)
from repro.ft.inject import (DeviceJoin, DeviceLoss,  # noqa: E402
                             HungCollective)

# unique per invocation: concurrent runs (e.g. the fast-tier --dry test
# next to a full run) must not share checkpoint state
CKPT = tempfile.mkdtemp(prefix="repro_elastic_demo_")


def build_tc(steps):
    model = dataclasses.replace(
        get_reduced("tinyllama-1.1b"), name="llama-elastic",
        num_layers=2 if DRY else 16,
        d_model=128, num_heads=4, num_kv_heads=2, d_ff=352,
        vocab_size=1024)
    return TrainConfig(
        model=model,
        shape=ShapeConfig("train_64", 64, 16, "train"),
        plan=ParallelPlan(pp_axis="pp", schedule="chronos", num_chunks=2,
                          microbatch_size=2),
        optimizer=OptimizerConfig(lr=1e-3, warmup_steps=5,
                                  total_steps=steps, schedule="constant"),
        log_every=5 if DRY else 10, checkpoint_every=3 if DRY else 10,
        checkpoint_dir=CKPT, keep_checkpoints=3)


def main():
    from repro.ft.elastic_pipeline import train_elastic
    steps = 6 if DRY else 40
    if DRY:
        faults = [DeviceLoss(step=3, device=1),
                  DeviceJoin(step=5, device=1)]
        expect_ps = [2, 1, 2]
    else:
        faults = [DeviceLoss(step=15, device=5),
                  HungCollective(step=24, device=2, hang_s=900.0),
                  DeviceJoin(step=32, device=5),
                  DeviceJoin(step=32, device=2)]
        expect_ps = [16, 15, 14, 15, 16]

    print(f"=== elastic pipeline run: {N_DEV} devices, {steps} steps, "
          f"{len(faults)} injected faults ===")
    out = train_elastic(build_tc(steps), n_devices=N_DEV, faults=faults,
                        steps=steps, watchdog_timeout=600.0)

    ps = [inc["P"] for inc in out["incarnations"]]
    print(f"incarnations (P): {ps}")
    for r in out["recoveries"]:
        print(f"  {r.kind}: P={r.p_from}->{r.p_to} at step {r.step} | "
              f"detect {r.detect_s * 1e3:.0f}ms "
              f"replan {r.replan_s * 1e3:.0f}ms "
              f"restore {r.restore_s * 1e3:.0f}ms "
              f"remap {r.remap_s * 1e3:.0f}ms "
              f"resume {r.resume_s * 1e3:.0f}ms")
    assert ps == expect_ps, f"expected {expect_ps}, got {ps}"
    assert sorted(out["loss_by_step"]) == list(range(steps)), \
        "run is not step-count-exact"
    losses = out["losses"]
    print(f"loss: {losses[0]:.4f} -> {losses[-1]:.4f} across "
          f"{len(out['incarnations'])} incarnations")
    assert losses[-1] < losses[0], "loss did not decrease across restarts"
    assert len(out["recoveries"]) == len(faults), \
        "every injected fault should produce one recovery record"
    print("elastic pipeline recovery OK: kill -> re-plan -> migrate -> "
          "resume -> scale-up, step-count-exact")


if __name__ == "__main__":
    try:
        main()
    finally:
        shutil.rmtree(CKPT, ignore_errors=True)
