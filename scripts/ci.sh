#!/usr/bin/env bash
# Fast tier-1 CI entry.
#
# 1. Best-effort install of the package + `test` extra (hypothesis).
#    The pinned accelerator container has no network: the suite then
#    falls back to tests/helpers/hypcompat.py's degraded deterministic
#    sampling, so collection never breaks on the missing dev dep.
# 2. Run the fast suite (slow marker deselected) through the same entry
#    the benchmark harness uses (benchmarks/run.py --check).
#
# Full suite (all @slow cases, ~10+ min on CPU):
#   RUN_SLOW=1 PYTHONPATH=src python -m pytest -q
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -e ".[test]" >/dev/null 2>&1 \
    || echo "ci.sh: pip install skipped (offline?) — using installed deps"

exec python benchmarks/run.py --check "$@"
