#!/usr/bin/env bash
# Fast tier-1 CI entry.
#
# 1. Best-effort install of the package + `test` extra (hypothesis).
#    The pinned accelerator container has no network: the suite then
#    falls back to tests/helpers/hypcompat.py's degraded deterministic
#    sampling, so collection never breaks on the missing dev dep.
# 2. Analytical-layer import smoke: the schedule IR, every generator
#    (incl. repro.core.vshape / repro.seqpipe.schedules via the
#    registry), and the planner must import with jax POISONED — the
#    lazy-import guarantee PR 3 established for core.schedules,
#    enforced here for the whole analytical layer.
# 3. Docs step: the schedule gallery (docs/SCHEDULES.md) is generated
#    from the registered generators — regenerate and fail on diff —
#    and the docs' `>>>` code blocks run under doctest.
# 3b. Executor perf record: benchmarks/pipeline_exec.py --check
#    re-measures the legacy vs phase-compiled executor on the
#    acceptance cell (chronos P=4 v=2 m=8) every PR — including one
#    overlapped+compressed wire cell (double-buffered exchange, int8
#    boundary payloads) — and writes BENCH_pipeline_exec_check.json
#    (the committed full-matrix record BENCH_pipeline_exec.json, with
#    the overlap/wire axes and the pp4 x dp2 mesh family, is refreshed
#    by running the script without --check).
# 3c. Elastic-recovery perf record: benchmarks/ft_recovery.py --check
#    replays the deterministic fault drill (checkpoint-writer crash,
#    device loss -> re-plan at P-1 -> restore/remap -> resume, rejoin
#    -> scale-up) on 2 forced-host devices and writes
#    BENCH_ft_recovery_check.json (the committed full record
#    BENCH_ft_recovery.json is refreshed by running without --check).
# 3d. Serving perf record: benchmarks/serve.py --check serves seeded
#    Poisson traffic through the pipelined engine (seq-chunked prefill
#    + steady-tick decode, continuous batching) at two arrival rates
#    on 2 forced-host devices and writes BENCH_serve_check.json (the
#    committed full record BENCH_serve.json is refreshed by running
#    without --check).
# 3e. Resilient-serving perf record: benchmarks/serve_resilience.py
#    --check serves a bursty trace with deadlines under an injected
#    slot corruption + mid-decode device loss (elastic P=2 -> 1
#    recovery with re-prefill re-admission) and writes
#    BENCH_serve_resilience_check.json (the committed full P=3 -> 2
#    record BENCH_serve_resilience.json is refreshed by running
#    without --check).
# 4. Run the fast suite (slow marker deselected) through the same entry
#    the benchmark harness uses (benchmarks/run.py --check).  The
#    fault-injection suite (tests/test_ft_and_data.py crash-consistency
#    + injector cases, tests/test_elastic_pipeline.py remap/recovery
#    drills) rides in tier-1; only the 16-device example run is @slow.  The
#    repro.seqpipe tests ride in tier-1 with the same slow split: IR /
#    table / planner / prefix-KV-attention unit tests plus the
#    `split_fused_check.py --pair seq` SPMD gradient equivalence and
#    the trace-only seq train-step check stay fast (< ~1 min), while
#    the single-device-autodiff pipeline comparisons and the multi-step
#    seq training driver run under @slow.
#
# Full suite (all @slow cases, ~10+ min on CPU):
#   RUN_SLOW=1 PYTHONPATH=src python -m pytest -q
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -e ".[test]" >/dev/null 2>&1 \
    || echo "ci.sh: pip install skipped (offline?) — using installed deps"

PYTHONPATH=src python -c "
import sys
sys.modules['jax'] = None          # poison: any 'import jax' raises
sys.modules['jaxlib'] = None
import repro.core.schedule, repro.core.schedules, repro.plan
import repro.serve                 # admission layer + traffic gen
import repro.serve.resilience      # recovery records + fault specs
import repro.ft                    # health / injection decision layer
from repro.ft import FaultInjector, HealthMonitor, Watchdog
from repro.serve import SlotScheduler, bursty_requests, parse_fault_spec
"
echo "ci.sh: analytical layer (schedule IR, generators, planner, serve scheduler, ft decision layer) imports jax-free"

PYTHONPATH=src python scripts/render_schedules.py --check
PYTHONPATH=src python -m doctest docs/ARCHITECTURE.md docs/SCHEDULES.md
echo "ci.sh: docs gallery in sync; doctests passed"

python benchmarks/pipeline_exec.py --check
echo "ci.sh: executor perf record regenerated (BENCH_pipeline_exec_check.json)"

python benchmarks/ft_recovery.py --check
echo "ci.sh: elastic-recovery perf record regenerated (BENCH_ft_recovery_check.json)"

python benchmarks/serve.py --check
echo "ci.sh: pipelined-serving perf record regenerated (BENCH_serve_check.json)"

python benchmarks/serve_resilience.py --check
echo "ci.sh: resilient-serving perf record regenerated (BENCH_serve_resilience_check.json)"

exec python benchmarks/run.py --check "$@"
