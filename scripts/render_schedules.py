#!/usr/bin/env python
"""Render the schedule gallery (docs/SCHEDULES.md) from the registered
generators, so the docs regenerate from code and cannot go stale.

    PYTHONPATH=src python scripts/render_schedules.py          # rewrite
    PYTHONPATH=src python scripts/render_schedules.py --check  # CI diff

Timeline notation (one character per half-grain, time left to right,
one row per pipeline stage):

    F0 / f1   forward of microbatch 0 / 1 (upper case = chunk 0,
              lower case = chunk 1; the kind letter marks the first
              half-grain, the microbatch digit fills the rest)
    B0 / b0   backward (input-gradient step for split-backward
              schedules; rB000 = legacy recompute *prefix* inside B)
    W0 / w0   deferred weight-gradient (split-backward family)
    R0 / r0   explicit rematerialization replay (Chronos-Recomp)
    .         idle (bubble)
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import schedules as S  # noqa: E402
from repro.core.schedule import Schedule, to_half  # noqa: E402

DOC = os.path.join(os.path.dirname(__file__), "..", "docs", "SCHEDULES.md")

# every REGISTRY generator appears at least once (checked below)
GALLERY = [
    ("gpipe", dict(P=4, m=6),
     "All forwards, flush, all backwards — m/P x activation residency."),
    ("1f1b", dict(P=4, m=6),
     "DAPPLE one-forward-one-backward; peak activation m_a on stage 0."),
    ("1f1b", dict(P=4, m=6, recomp=0.5),
     "1F1B + uniform 50% recompute: every backward carries a replay "
     "prefix (`r`), halving stored activations."),
    ("interleaved", dict(P=4, m=4, v=2),
     "Megatron interleaved 1F1B (virtual pipeline) — lower bubble, "
     "*higher* peak activation than 1F1B."),
    ("chronos", dict(P=4, m=4, v=2),
     "Paper section 4.1 periodic slot classes: shallow chunk (upper case) "
     "launched late / retired early => ~75% m_a at large P."),
    ("chronos_recomp", dict(P=4, m=4),
     "Paper section 4.2: the shallowest chunk replays from its boundary "
     "checkpoint — explicit `R` ticks right before each `B` => 25% m_a."),
    ("chronos_zero2", dict(P=4, m=4, v=2, group=2),
     "Paper section 4.3 grouped chunk re-launches: same-(kind, chunk) "
     "tasks of a microbatch group run back-to-back for ZeRO-2 DP "
     "collectives."),
    ("zb_h1", dict(P=4, m=6),
     "ZB-H1 split backward: `B` = input-gradient (releases the "
     "activation), `W` = deferred weight-gradient filling the cooldown "
     "bubble at 1F1B's peak activation."),
    ("chronos_zb", dict(P=4, m=4, v=2),
     "Chronos slot classes with the backward split: freed grains plus "
     "the alignment bubbles absorb the `W` tasks — same span, more "
     "useful compute."),
    ("seq1f1b", dict(P=4, m=3, n_seq=2),
     "Sequence-chunked 1F1B (`repro.seqpipe`): every microbatch splits "
     "into `n_seq` causally-ordered chunks — forwards hand a KV prefix "
     "down the stage (ascending seq order), backwards accumulate dKV "
     "(descending) — so ~P *chunk* units are in flight instead of P "
     "microbatches: peak activation ~1/n_seq at a *better* bubble."),
    ("chronos_seq", dict(P=4, m=2, v=2, n_seq=2),
     "Chronos periodic slot classes over sequence-chunk units: the "
     "backward phase shifts by n_seq-1 cycles and runs each "
     "microbatch's chunks in reverse, keeping the shallow-chunk "
     "temporal locality per unit."),
    ("v_min", dict(P=4, m=4),
     "V-shape fold-back placement (device d holds blocks d and 2P-1-d; "
     "rows are *devices*): the just-in-time repeating unit FFBWBW holds "
     "(4P+2)/6 in-flight units per device in steady state — ~1/3 of "
     "1F1B's peak at depth (0.375 at P=8), though at this toy P=4 the "
     "warm-up transient raises the measured peak to 0.5 — at the "
     "longest warm-up ramp of the family."),
    ("v_half", dict(P=4, m=4),
     "The controllable-memory middle point: eager forwards under a "
     "ceil(P/2) in-flight cap released at the deep chunk's backward — "
     "peak exactly ceil(P/2)/P of m_a, roughly half of v_min's ramp."),
    ("v_zb", dict(P=4, m=4),
     "Eager forwards under a P in-flight cap: 1F1B-level peak "
     "activation and the ideal ZB ramp (the warm-up packs completely; "
     "deferred W tasks fill the cool-down)."),
]

KIND_GLYPH = {"F": "F", "B": "B", "W": "W", "R": "R"}


def render_timeline(sched: Schedule) -> str:
    """ASCII timeline, one row per *device*, one char per half-grain.
    Devices coincide with stages under the interleaved placement (rows
    labelled ``stage``); placement-carrying schedules (the V family)
    label rows ``dev`` — each device then runs tasks of two stages."""
    t0 = min(to_half(t.start) for t in sched.tasks)
    t1 = max(to_half(t.end) for t in sched.tasks)
    label = "stage" if sched.placement is None else "dev"
    rows = []
    for d in range(sched.P):
        row = ["."] * (t1 - t0)
        for t in sched.device_tasks(d):
            a, b = to_half(t.start) - t0, to_half(t.end) - t0
            glyph = KIND_GLYPH[t.kind]
            if t.chunk % 2 == 1:
                glyph = glyph.lower()
            rech = to_half(t.recomp)
            cells = ["r"] * rech + [glyph] + \
                [str(t.mb % 10)] * (b - a - rech - 1)
            for i, ch in enumerate(cells):
                assert row[a + i] == ".", \
                    f"overlap on device {d}, half-grain {a + i}"
                row[a + i] = ch
        rows.append(f"{label} {d} |" + "".join(row) + "|")
    return "\n".join(rows)


def metrics_block(sched: Schedule) -> str:
    lines = [
        f"- span: {sched.total_time():g} grains "
        f"({sched.total_time_rel():.3g} T_fwd); "
        f"bubble {sched.bubble_ratio():.1%}; "
        f"ideal-compute {sched.ideal_compute_fraction():.1%}",
        f"- peak activation: {sched.peak_activation(count_transient=False):.4g}"
        f" m_a (per-device max, paper accounting)",
    ]
    extra = []
    if sched.placement is not None:
        extra.append(f"placement: {sched.placement.name} "
                     f"({sched.placement.describe()})")
    if sched.has_w:
        extra.append("split backward (B/W)")
    if sched.has_r:
        extra.append(f"explicit recompute of chunks "
                     f"{sorted(sched.r_chunks())} (R tasks)")
    if sched.n_seq > 1:
        extra.append(f"{sched.n_seq} sequence chunks per microbatch "
                     f"(KV-prefix / dKV deps, repro.seqpipe)")
    if extra:
        lines.append(f"- {'; '.join(extra)}")
    lines.append(f"- {phase_note(sched)}")
    return "\n".join(lines)


def phase_note(sched: Schedule) -> str:
    """Phase factorization of the compiled task table (the executor's
    warmup / steady-period / cooldown segmentation; see
    `repro.core.tasktable.factor_phases`).  Rendered at the gallery's
    toy sizes — the steady compression grows with m while warmup,
    period and cooldown stay fixed."""
    from repro.core.tasktable import build_task_table, factor_phases
    plan = factor_phases(build_task_table(sched))
    if not plan.period:
        return (f"phase factorization: no steady period at this toy m "
                f"({plan.T} ticks; larger m exposes one)")
    cool = plan.T - plan.cooldown_start
    return (f"phase factorization: {plan.T} ticks = warmup {plan.warmup} "
            f"+ {plan.n_periods} x period {plan.period} (mb stride "
            f"{plan.mb_stride}) + cooldown {cool} — compressed op-stream "
            f"{plan.compressed_ticks} ticks")


def render_doc() -> str:
    out = [
        "# Schedule gallery",
        "",
        "<!-- GENERATED FILE — edit scripts/render_schedules.py, then run",
        "     `PYTHONPATH=src python scripts/render_schedules.py`.",
        "     CI regenerates and fails on diff. -->",
        "",
        "Every generator registered in `repro.core.schedules.REGISTRY`,",
        "constructed small and rendered as ASCII timelines (one row per",
        "stage, one character per half-grain, time left to right).",
        "",
        "Notation: `F0`/`f1` forward of microbatch 0/1 (upper case =",
        "chunk 0, lower = chunk 1; the letter marks the first half-grain,",
        "the microbatch digit fills the rest), `B`/`b` backward",
        "(input-gradient only in the split-backward family), `W`/`w`",
        "deferred weight-gradient, `R`/`r`-followed-by-digits explicit",
        "rematerialization replay, a leading `r` inside a backward the",
        "legacy uniform-recompute prefix, `.` idle.",
        "",
    ]
    covered = set()
    for name, kw, blurb in GALLERY:
        covered.add(name)
        sched = S.get_schedule(name, **kw)
        args = ", ".join(f"{k}={v}" for k, v in kw.items())
        out += [f"## `{sched.name}` — `get_schedule(\"{name}\", {args})`",
                "", blurb, "", "```text", render_timeline(sched), "```",
                "", metrics_block(sched), ""]
    missing = set(S.REGISTRY) - covered
    assert not missing, f"gallery missing registered generators: {missing}"
    return "\n".join(out) + "\n"


def main() -> int:
    doc = render_doc()
    check = "--check" in sys.argv
    if check:
        old = open(DOC).read() if os.path.exists(DOC) else ""
        if old != doc:
            sys.stderr.write(
                "docs/SCHEDULES.md is stale — run "
                "`PYTHONPATH=src python scripts/render_schedules.py`\n")
            return 1
        print("docs/SCHEDULES.md up to date")
        return 0
    os.makedirs(os.path.dirname(DOC), exist_ok=True)
    with open(DOC, "w") as f:
        f.write(doc)
    print(f"wrote {os.path.normpath(DOC)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
