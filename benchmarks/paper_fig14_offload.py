"""Fig. 14 — Chronos-Offload scalability on a 16-layer model, global
batch 128, micro batch 2.

Paper: at PP4_TP8 seq 4K only 45.45% of the offload work overlaps the
cooldown bubbles; doubling PP -> 94.55%; doubling seq -> 100%.

Our model calibrates the single free constant (accelerator FLOP/s) on
the first point, then *predicts* the other two.
"""
from __future__ import annotations

import dataclasses

from repro.configs.llama70b_paper import with_layers
from repro.core.analysis import offload_timing

CFG = with_layers(16)


def _overlap(pp, seq, gpu_flops):
    t = offload_timing(CFG, seq_len=seq, microbatch=2, pp=pp, tp=8,
                       gpu_flops=gpu_flops, pcie_gbps=32.0)
    return t.overlap_ratio


def calibrate(target=0.4545):
    lo, hi = 1e12, 2e15
    for _ in range(60):
        mid = (lo * hi) ** 0.5
        if _overlap(4, 4096, mid) > target:
            lo = mid
        else:
            hi = mid
    return (lo * hi) ** 0.5


def rows():
    flops = calibrate()
    return {
        "gpu_flops_calibrated_TF": flops / 1e12,
        "pp4_seq4k (paper 45.45%)": _overlap(4, 4096, flops),
        "pp8_seq4k (paper 94.55%)": _overlap(8, 4096, flops),
        "pp4_seq8k (paper 100%)": _overlap(4, 8192, flops),
    }


def run(bench):
    r = rows()
    for k, v in r.items():
        bench.add(f"fig14_{k}", lambda v=v: round(v, 4))
    return r
