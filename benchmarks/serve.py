"""Pipelined-serving benchmark: throughput + latency under synthetic
Poisson traffic.

Serves seeded Poisson request traces (``repro.serve.poisson_requests``)
through the pipelined engine (seq-chunked prefill + steady-tick decode
with continuous batching) at several arrival rates and records, per
rate: tokens/sec, TTFT p50/p99 and per-token latency p50/p99 (wall
clock, compile excluded by a warmup trace).  The full run (``P=4``,
three rates) writes ``BENCH_serve.json`` at the repo root; ``--check``
is the CI smoke (``P=2``, two rates, shorter trace) and writes
``BENCH_serve_check.json`` so the committed full record is never
clobbered — ``scripts/ci.sh`` runs it every PR.

Must run standalone: the virtual devices require
``XLA_FLAGS=--xla_force_host_platform_device_count`` before jax import.
"""
import argparse
import os
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--check", action="store_true",
                help="CI smoke: P=2, two rates, short trace")
ap.add_argument("--devices", type=int, default=0)
ap.add_argument("--requests", type=int, default=0)
args = ap.parse_args()
P = args.devices or (2 if args.check else 4)
NREQ = args.requests or (6 if args.check else 16)
RATES = (4.0, 32.0) if args.check else (1.0, 4.0, 16.0)

if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={P}"

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO)
sys.path.insert(0, os.path.join(REPO, "src"))

from benchmarks.run import write_json  # noqa: E402

CHUNK = 8
MAX_SEQ = 64
ARCH = "tinyllama-1.1b"


def main():
    import jax
    from repro.configs import get_reduced
    from repro.models import LM
    from repro.serve import PipelinedEngine, poisson_requests, summarize

    cfg = get_reduced(ARCH)
    lm = LM(cfg)
    params, _ = lm.init(jax.random.key(0))
    eng = PipelinedEngine(cfg, params, P=P, chunk=CHUNK, max_seq=MAX_SEQ,
                          n_slots=P)

    def traffic(rate, seed):
        return poisson_requests(NREQ, rate, chunk=CHUNK, max_seq=MAX_SEQ,
                                prompt_range=(1, 3),
                                gen_range=(4, 8 if args.check else 16),
                                vocab=cfg.vocab_size, seed=seed)

    # warmup: compile both branch shapes (prefill + decode) off the clock
    eng.serve(traffic(100.0, seed=99)[:2], clock=None)

    rows = []
    for rate in RATES:
        res = eng.serve(traffic(rate, seed=17))
        s = summarize(res)
        assert s["requests"] == NREQ, "requests lost"
        rows.append((f"rate{rate:g}.tokens_per_s",
                     1e6 / max(s["tokens_per_s"], 1e-9),
                     {"tokens_per_s": round(s["tokens_per_s"], 1),
                      "requests": s["requests"],
                      "output_tokens": s["output_tokens"],
                      "ticks": s["ticks"]}))
        rows.append((f"rate{rate:g}.ttft", s["ttft_p50_s"] * 1e6,
                     {"p50_s": round(s["ttft_p50_s"], 4),
                      "p99_s": round(s["ttft_p99_s"], 4)}))
        rows.append((f"rate{rate:g}.per_token", s["tok_p50_s"] * 1e6,
                     {"p50_ms": round(s["tok_p50_s"] * 1e3, 2),
                      "p99_ms": round(s["tok_p99_s"] * 1e3, 2)}))
    name = "serve_check" if args.check else "serve"
    path = write_json(name, rows)
    for n, us, derived in rows:
        print(f"{n},{us:.1f},{derived}")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
