"""Fig. 13 — P2P overhead on a 24-layer model at (PP,TP)=(4,8), micro
batch 2, seq 4K, Tc ~= 0.104 T_unit.

Paper: Chronos-Pipe's ideal computation fraction is ~6% below 1F1B (5%
of which is P2P: one extra round of communication); Chronos-Recomp lands
within <=3% of 1F1B+R=50%.
"""
from __future__ import annotations

from repro.core import schedules as S
from repro.core.schedule import retime_with_comm

PP, M, TC = 4, 32, 0.104


def rows():
    f1 = retime_with_comm(S.onef1b(PP, M), TC / 2, sync=True)
    ch = retime_with_comm(S.chronos(PP, M, 2), TC, sync=True)
    r50 = retime_with_comm(S.onef1b(PP, M, recomp=0.5), TC / 2, sync=True)
    cr = retime_with_comm(S.chronos_recomp(PP, M), TC, sync=True)
    # beyond-paper: async P2P (XLA collective-permute overlap)
    ch_async = retime_with_comm(S.chronos(PP, M, 2), TC, sync=False)
    return {
        "1f1b": f1.ideal_compute_fraction(),
        "chronos": ch.ideal_compute_fraction(),
        "1f1b+R=50%": r50.ideal_compute_fraction(),
        "chronos+recomp": cr.ideal_compute_fraction(),
        "chronos_asyncP2P": ch_async.ideal_compute_fraction(),
    }


def run(bench):
    r = rows()
    for k, v in r.items():
        bench.add(f"fig13_icf_{k}", lambda v=v: round(v, 4))
    bench.add("fig13_chronos_drop_vs_1f1b (paper ~6%)",
              lambda: round(r["1f1b"] - r["chronos"], 4))
    bench.add("fig13_recomp_gap_vs_r50 (paper <=3%)",
              lambda: round(abs(r["1f1b+R=50%"] - r["chronos+recomp"]), 4))
    bench.add("fig13_async_beats_sync (beyond paper)",
              lambda: round(r["chronos_asyncP2P"] - r["chronos"], 4))
    return r
