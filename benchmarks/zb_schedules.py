"""Split-backward (B/W) schedule family vs the fused baselines.

Beyond-paper table: for P in {4, 8} at m = 4P, compare 1F1B / Chronos /
ZB-H1 / Chronos-ZB on steady-state bubble, peak activation (units of
m_a), and total time (units of T_fwd).  Expected shape:

- ``zb_h1``      : ~1/3 of 1F1B's bubble at identical peak activation
                   (the ZB-H1 bound (p-1)(f+b_in-w), hit exactly).
- ``chronos_zb`` : chronos' span and chronos' peak activation, with the
                   fused backward split so the freed grains + alignment
                   bubbles run deferred W tasks — same bubble ratio,
                   strictly less of it on the critical path between B
                   tasks (weight grads move off the grad dependency
                   chain, which is what lets DP overlap / offload eat
                   the W slots).
"""
from __future__ import annotations

from repro.core import analysis as AN
from repro.core import schedules as S

PP_LIST = (4, 8)


def rows():
    out = {}
    for P in PP_LIST:
        m = 4 * P
        scheds = {
            "1f1b": S.onef1b(P, m),
            "chronos": S.chronos(P, m, 2),
            "zb_h1": S.zb_h1(P, m),
            "chronos_zb": S.chronos_zb(P, m, 2),
        }
        for name, sc in scheds.items():
            out[(P, name)] = {
                "bubble": sc.bubble_ratio(),
                "peak_act": sc.peak_activation(),
                "time_rel": sc.total_time_rel(),
            }
    return out


def run(bench):
    r = rows()
    for (P, name), d in sorted(r.items()):
        bench.add(f"zb_P{P}_{name}",
                  lambda d=d: {k: round(v, 4) for k, v in d.items()})
    for P in PP_LIST:
        bench.add(
            f"zb_P{P}_h1_bubble_vs_formula ((p-1)/((p-1)+3m))",
            lambda P=P: (round(r[(P, 'zb_h1')]['bubble'], 4),
                         round(AN.zb_h1_bubble(P, 4 * P), 4)))
        bench.add(
            f"zb_P{P}_h1_vs_1f1b_bubble_ratio (paper ~1/3)",
            lambda P=P: round(r[(P, 'zb_h1')]['bubble']
                              / r[(P, '1f1b')]['bubble'], 3))
    return r
