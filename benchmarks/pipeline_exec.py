"""Executor micro-benchmark: legacy per-tick interpreter vs the
phase-compiled executor (PR 5's tentpole), measured per schedule family,
and — per family — three more axes:

- ``kernels``: ``"xla"`` vs ``"fused"`` (the repro.models.backend seam
  dispatching the Pallas kernel library; interpret=True on this CPU
  host, so the fused column measures seam + interpret overhead, not TPU
  kernel speed),
- ``overlap``: synchronous in-tick exchange vs the double-buffered
  (deferred) wire — the overlap table stretches cross-device deps to a
  2-tick gap, so on this shared-memory host the column prices the skew
  ticks the deferral adds, while on a real fabric it hides the p2p
  latency,
- ``wire`` (chronos only): boundary-payload dtype on the packed uint16
  wire — fp32 (bitwise), bf16, int8-with-scale.

A subprocess re-exec with 8 forced host devices adds a multi-axis
``pp4 x dp2`` mesh row family (the full-manual shard_map fallback on
the pinned jaxlib), phase executor, sync + overlapped wire.

For each cell this records

- **trace_s** — ``jax.jit(fn).lower(...)`` wall time (Python tracing),
- **compile_s** — ``lowered.compile()`` wall time (XLA),
- **steady_ms** — steady-state per-step wall-clock: min over
  ``--reps`` calls of the compiled step, best of ``--rounds``
  interleaved rounds (interleaving de-biases machine drift; min-of-N is
  the standard steady-state estimator on a shared host),
- **steady_cpu_ms** — the same step's process-CPU time (less sensitive
  to scheduling noise),
- **predicted_grains** — ``sum(analysis.predicted_tick_costs(...))``,
  the analytic lockstep cost of the table (max task duration per tick),
- **grain_us** — steady_ms / predicted_grains: the executor's effective
  grain time.  Comparing it across families separates schedule compute
  (expected) from executor overhead; comparing it across the kernels
  column prices the fused backend per family.

Writes ``BENCH_pipeline_exec.json`` (schema ``{bench, rows, host,
commit}``) at the repo root and prints a summary table.  ``--check``
runs the smoke matrix (the acceptance cell ``chronos P=4 v=2 m=8``
only, fewer reps, plus one overlapped+compressed wire cell) and writes
``BENCH_pipeline_exec_check.json`` so the committed full-matrix record
is never clobbered by a smoke run — ``scripts/ci.sh`` runs the smoke
every PR so perf numbers regenerate alongside the code.

Must run as a standalone script: the virtual pipeline devices require
``XLA_FLAGS=--xla_force_host_platform_device_count`` before jax import.
"""
import argparse
import json
import os
import platform
import subprocess
import sys
import time

P_DEVICES = 4

if __name__ == "__main__":
    _NDEV = 8 if "--mesh-family" in sys.argv else P_DEVICES
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={_NDEV}")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

FULL_MATRIX = (
    # family, schedule kwargs, v, n_seq
    ("chronos", {}, 2, 1),
    ("1f1b", {}, 1, 1),
    ("zb_h1", {}, 1, 1),
    ("chronos_recomp", {"rho": 1.0, "recomp_chunks": 1}, 2, 1),
    ("v_min", {}, 2, 1),
    ("chronos_seq", {}, 2, 2),
)
SMOKE_MATRIX = FULL_MATRIX[:1]

SYNC = ("phase", "xla", False, "fp32")
OVERLAP = ("phase", "xla", True, "fp32")


def family_axes(family, check=False):
    """(executor, kernels, overlap, wire) cells for a schedule family.

    The kernels axis rides the phase executor only (the legacy
    interpreter is the xla-backend baseline); the overlap and wire axes
    ride phase/xla.  Compressed wires are measured on the acceptance
    family (chronos) only — the protocol is schedule-independent."""
    if check:
        return (("legacy", "xla", False, "fp32"), SYNC,
                ("phase", "fused", False, "fp32"),
                ("phase", "xla", True, "int8"))   # overlapped+compressed
    axes = [("legacy", "xla", False, "fp32"), SYNC, OVERLAP,
            ("phase", "fused", False, "fp32")]
    if family == "chronos":
        axes += [("phase", "xla", True, "bf16"),
                 ("phase", "xla", True, "int8")]
    return tuple(axes)


def bench_cell(spec, sched, mesh, params, batch, executor, reps,
               rules=None):
    import jax

    from repro.core.analysis import predicted_tick_costs
    from repro.core.pipeline_runtime import make_train_grads_fn
    from repro.models import shard_env
    with shard_env(mesh, rules or {}):
        fn = make_train_grads_fn(spec, mesh, executor=executor)
        t0 = time.perf_counter()
        lowered = jax.jit(fn).lower(params, batch)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        t2 = time.perf_counter()
        jax.block_until_ready(compiled(params, batch))
        ts = []
        for _ in range(reps):
            ta, ca = time.perf_counter(), time.process_time()
            jax.block_until_ready(compiled(params, batch))
            ts.append((time.perf_counter() - ta,
                       time.process_time() - ca))
    grains = float(predicted_tick_costs(sched, spec.table).sum())
    steady = min(t[0] for t in ts)
    return {"trace_s": round(t1 - t0, 3),
            "compile_s": round(t2 - t1, 3),
            "steady_ms": round(steady * 1e3, 1),
            "steady_cpu_ms": round(min(t[1] for t in ts) * 1e3, 1),
            "predicted_grains": round(grains, 1),
            "grain_us": round(steady * 1e6 / grains, 1)}


def run(check=False, reps=None, rounds=None, json_out=None,
        mesh_family=False):
    import jax

    from repro.configs import get_reduced
    from repro.core.pipeline_runtime import (init_pipeline_params,
                                             make_pipeline_spec)
    from repro.core.schedules import get_schedule
    from repro.jax_compat import make_mesh

    matrix = SMOKE_MATRIX if (check or mesh_family) else FULL_MATRIX
    reps = reps or (6 if check else 12)
    rounds = rounds or (2 if (check or mesh_family) else 3)
    P_, m, mbB, S = P_DEVICES, 8, 2, 17
    cfg = get_reduced("tinyllama-1.1b")
    if mesh_family:
        # pp4 x dp2 (x model=1) on 8 forced host devices: exercises the
        # full-manual shard_map fallback (pinned jaxlib) end to end
        mesh = make_mesh((P_, 2, 1), ("pp", "data", "model"))
        rules = {"dp": "data", "tp": "model", "fsdp": None}
    else:
        mesh = make_mesh((P_,), ("pp",))
        rules = {}

    cells = {}
    for family, kw, v, n_seq in matrix:
        axes = ((SYNC, OVERLAP) if mesh_family
                else family_axes(family, check))
        specs = {(kern, ov, wire): make_pipeline_spec(
            cfg, P=P_, v=v, m=m, microbatch=mbB, seq_len=S,
            schedule=family, n_seq=n_seq, kernels=kern, overlap=ov,
            wire=wire, **kw)
            for kern, ov, wire in {(k, o, w) for _, k, o, w in axes}}
        vkw = {"v": v} if family in ("chronos", "chronos_recomp",
                                     "chronos_seq") else {}
        if n_seq > 1:
            vkw["n_seq"] = n_seq
        sched = get_schedule(family, P_, m, **vkw, **kw)
        params, _ = init_pipeline_params(
            jax.random.key(0), cfg, specs[("xla", False, "fp32")].layout)
        tokens = jax.random.randint(jax.random.key(1), (m, mbB, S), 0,
                                    cfg.vocab_size)
        cells[family] = (specs, axes, sched, params, {"tokens": tokens})

    # aggregation: MEDIAN across rounds for the one-shot costs (trace /
    # compile vary with environmental noise; the median is the robust
    # central estimate), MIN for the steady-state step (the standard
    # steady-state estimator — the fastest observed step is the one
    # least disturbed by the host).
    import statistics
    rows = []
    best = {}
    for rnd in range(rounds):
        for family, (specs, axes, sched, params, batch) in cells.items():
            for executor, kern, ov, wire in axes:
                best.setdefault((family, executor, kern, ov, wire),
                                []).append(
                    bench_cell(specs[(kern, ov, wire)], sched, mesh,
                               params, batch, executor, reps,
                               rules=rules))
    agg = {}
    for key, rs in best.items():
        agg[key] = {
            "trace_s": round(statistics.median(
                r["trace_s"] for r in rs), 3),
            "compile_s": round(statistics.median(
                r["compile_s"] for r in rs), 3),
            "steady_ms": min(r["steady_ms"] for r in rs),
            "steady_cpu_ms": min(r["steady_cpu_ms"] for r in rs),
            "predicted_grains": rs[0]["predicted_grains"],
        }
        agg[key]["grain_us"] = round(
            agg[key]["steady_ms"] * 1e3
            / agg[key]["predicted_grains"], 1)
    best = agg
    mesh_name = "pp4xdp2" if mesh_family else "pp4"
    for (family, executor, kern, ov, wire), r in best.items():
        rows.append({"family": family, "P": P_, "m": m,
                     "v": cells[family][0][("xla", False,
                                            "fp32")].layout.v,
                     "mesh": mesh_name, "executor": executor,
                     "kernels": kern, "overlap": ov, "wire": wire, **r})

    summary = {}
    for family in cells:
        if mesh_family:
            ph = best[(family, *SYNC)]
            ov = best[(family, *OVERLAP)]
            summary[f"{family}@{mesh_name}"] = {
                "overlap_steady_ratio": round(
                    ov["steady_ms"] / ph["steady_ms"], 2)}
            continue
        leg = best[(family, "legacy", "xla", False, "fp32")]
        ph = best[(family, *SYNC)]
        fu = best[(family, "phase", "fused", False, "fp32")]
        tc_ratio = (leg["trace_s"] + leg["compile_s"]) / \
            (ph["trace_s"] + ph["compile_s"])
        speedup = 1.0 - ph["steady_ms"] / leg["steady_ms"]
        summary[family] = {
            "trace_compile_ratio": round(tc_ratio, 2),
            "steady_speedup_pct": round(100 * speedup, 1),
            "steady_cpu_speedup_pct": round(
                100 * (1 - ph["steady_cpu_ms"] / leg["steady_cpu_ms"]),
                1),
            # fused-vs-xla grain on the phase executor (CPU interpret
            # overhead on this host; the TPU number is the interesting
            # one, this row just keeps the axis measured)
            "fused_grain_ratio": round(
                fu["grain_us"] / ph["grain_us"], 2),
        }
        ovl = best.get((family, *OVERLAP)) \
            or best.get((family, "phase", "xla", True, "int8"))
        if ovl is not None:
            # the deferred wire's cost on this shared-memory host: the
            # stretched table's skew ticks divided by the sync steady
            # (on a real fabric the hidden p2p latency flips the sign)
            summary[family]["overlap_steady_ratio"] = round(
                ovl["steady_ms"] / ph["steady_ms"], 2)

    if not (check or mesh_family):
        # multi-axis mesh family in a subprocess (needs 8 forced host
        # devices, which requires a fresh jax)
        import tempfile
        tmp = tempfile.mktemp(suffix=".json")
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        r = subprocess.run(
            [sys.executable, os.path.abspath(__file__), "--mesh-family",
             "--reps", str(reps), "--json-out", tmp],
            env=env, capture_output=True, text=True, timeout=3600)
        if r.returncode == 0:
            with open(tmp) as f:
                sub = json.load(f)
            rows.extend(sub["rows"])
            summary.update(sub["summary"])
            os.unlink(tmp)
        else:
            print(f"mesh-family subprocess failed:\n{r.stdout[-2000:]}\n"
                  f"{r.stderr[-2000:]}", file=sys.stderr)

    try:
        commit = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                                capture_output=True, text=True,
                                cwd=REPO).stdout.strip()
    except OSError:
        commit = "unknown"
    doc = {"bench": "pipeline_exec",
           "rows": rows,
           "host": {"platform": platform.platform(),
                    "python": platform.python_version(),
                    "jax": jax.__version__,
                    "cpus": os.cpu_count(),
                    "devices": 8 if mesh_family else P_DEVICES,
                    "mode": ("mesh" if mesh_family
                             else "check" if check else "full")},
           "commit": commit,
           "summary": summary}
    # the smoke run writes its own record: overwriting the committed
    # full-matrix trajectory with a 1-family smoke would lose it
    default_name = "BENCH_pipeline_exec_check.json" if check \
        else "BENCH_pipeline_exec.json"
    out_path = json_out or os.path.join(REPO, default_name)
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")

    hdr = (f"{'family':15s} {'mesh':8s} {'exec':6s} {'kern':5s} "
           f"{'ov':>2s} {'wire':5s} {'trace':>6s} {'compile':>8s} "
           f"{'steady':>9s} {'cpu':>9s} {'grain':>8s}")
    print(hdr)
    for r in rows:
        print(f"{r['family']:15s} {r['mesh']:8s} {r['executor']:6s} "
              f"{r['kernels']:5s} {int(r['overlap']):2d} {r['wire']:5s} "
              f"{r['trace_s']:5.2f}s {r['compile_s']:7.2f}s "
              f"{r['steady_ms']:7.1f}ms {r['steady_cpu_ms']:7.1f}ms "
              f"{r['grain_us']:6.1f}us")
    for family, s in summary.items():
        if "trace_compile_ratio" not in s:
            print(f"{family}: overlap steady "
                  f"{s['overlap_steady_ratio']}x")
            continue
        ov = s.get("overlap_steady_ratio")
        print(f"{family}: trace+compile {s['trace_compile_ratio']}x, "
              f"steady -{s['steady_speedup_pct']}% "
              f"(cpu -{s['steady_cpu_speedup_pct']}%), "
              f"fused grain {s['fused_grain_ratio']}x"
              + (f", overlap steady {ov}x" if ov else ""))
    print(f"wrote {out_path}")
    return doc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="smoke matrix (acceptance cell only, few reps)")
    ap.add_argument("--mesh-family", action="store_true",
                    help="pp4 x dp2 row family (needs 8 host devices; "
                         "run() re-execs this automatically)")
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    run(check=args.check, reps=args.reps, rounds=args.rounds,
        json_out=args.json_out, mesh_family=args.mesh_family)


if __name__ == "__main__":
    main()
