"""Resilient-serving benchmark: goodput, deadline hit rate, and
recovery phase timings under bursty traffic with injected faults.

Serves a seeded bursty trace (``repro.serve.bursty_requests`` — a
two-state modulated Poisson arrival process with a heavy generation
tail) with per-request deadlines and a bounded admission queue through
:func:`repro.serve.serve_resilient`, injecting a slot corruption and a
mid-decode device loss scheduled from a no-fault calibration pass.
Records, per scenario:

- lifecycle tallies (completed / expired / shed / failed, retry and
  preemption counts) and the deadline hit rate,
- goodput (completed-request tokens per wall second),
- per-recovery phase timings (detect / replan / remap / readmit /
  resume) for the elastic P-1 recovery.

The full run (``P=3 -> 2``) writes ``BENCH_serve_resilience.json`` at
the repo root; ``--check`` is the CI smoke (``P=2 -> 1``, shorter
trace) and writes ``BENCH_serve_resilience_check.json`` so the
committed full record is never clobbered — ``scripts/ci.sh`` runs it
every PR.

Must run standalone: the virtual devices require
``XLA_FLAGS=--xla_force_host_platform_device_count`` before jax import.
"""
import argparse
import os
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--check", action="store_true",
                help="CI smoke: P=2, shorter trace")
ap.add_argument("--devices", type=int, default=0)
ap.add_argument("--requests", type=int, default=0)
args = ap.parse_args()
P = args.devices or (2 if args.check else 3)
NREQ = args.requests or (8 if args.check else 20)

if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={P}"

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO)
sys.path.insert(0, os.path.join(REPO, "src"))

from benchmarks.run import write_json  # noqa: E402

CHUNK = 8
MAX_SEQ = 64
ARCH = "tinyllama-1.1b"
DEADLINE_S = 60.0          # generous: misses come from faults/overload
MAX_QUEUE = NREQ           # bound exists; sized to shed only bursts


def main():
    import jax
    from repro.configs import get_reduced
    from repro.ft import SlotCorruption, TickDeviceLoss
    from repro.models import LM
    from repro.serve import bursty_requests, serve_resilient, summarize

    cfg = get_reduced(ARCH)
    lm = LM(cfg)
    params, _ = lm.init(jax.random.key(0))

    def traffic(seed, deadline):
        return bursty_requests(
            NREQ, chunk=CHUNK, max_seq=MAX_SEQ, rate_lo=2.0,
            rate_hi=50.0, dwell_lo_s=0.5, dwell_hi_s=0.2,
            prompt_range=(1, 3), gen_range=(4, 8 if args.check else 12),
            gen_tail=0.2, deadline_s=deadline, vocab=cfg.vocab_size,
            seed=seed)

    quiet = lambda *_: None  # noqa: E731

    # calibration pass: no faults, tick-clock admission — compiles the
    # engine off the record and tells us where mid-decode is
    base = serve_resilient(cfg, params, traffic(17, None), P=P,
                           chunk=CHUNK, max_seq=MAX_SEQ, clock=None,
                           log=quiet)
    done = sorted(r.done_tick for r in base["finished"].values())
    loss_tick = done[0] + max(1, (done[-1] - done[0]) // 3)
    corrupt_tick = min(P + 3, max(2, loss_tick - 1))
    faults = [SlotCorruption(tick=corrupt_tick, slot=0),
              TickDeviceLoss(tick=loss_tick, device=P - 1)]

    res = serve_resilient(cfg, params, traffic(17, DEADLINE_S), P=P,
                          chunk=CHUNK, max_seq=MAX_SEQ, faults=faults,
                          max_queue=MAX_QUEUE, log=quiet)
    s = summarize(res)
    c = res["counts"]
    assert len(res["recoveries"]) == 1, "device loss did not fire"
    assert sum(c[k] for k in
               ("completed", "expired", "shed", "failed")) == NREQ, \
        "request lost (no terminal state)"

    rows = [
        ("bursty.goodput", 1e6 / max(s["goodput_tok_s"], 1e-9),
         {"goodput_tok_s": round(s["goodput_tok_s"], 1),
          "output_tokens": s["output_tokens"],
          "elapsed_s": round(s["elapsed_s"], 3),
          "ticks": res["ticks"]}),
        ("bursty.lifecycle", 1e6 * max(1, c["retries"]),
         {"completed": c["completed"], "expired": c["expired"],
          "shed": c["shed"], "failed": c["failed"],
          "retries": c["retries"], "preemptions": c["preemptions"]}),
        ("bursty.deadlines",
         1e6 * (1.0 - (s["deadline_hit_rate"] or 0.0)),
         {"with_deadline": c["with_deadline"],
          "hit_rate": None if s["deadline_hit_rate"] is None
          else round(s["deadline_hit_rate"], 3)}),
    ]
    for i, r in enumerate(res["recoveries"]):
        total = r.detect_s + r.replan_s + r.remap_s + r.readmit_s \
            + r.resume_s
        rows.append((f"recovery{i}.phases", total * 1e6,
                     {"kind": r.kind, "tick": r.tick,
                      "p": f"{r.p_from}->{r.p_to}",
                      "readmitted": r.n_readmitted,
                      "detect_ms": round(r.detect_s * 1e3, 1),
                      "replan_ms": round(r.replan_s * 1e3, 1),
                      "remap_ms": round(r.remap_s * 1e3, 1),
                      "readmit_ms": round(r.readmit_s * 1e3, 1),
                      "resume_ms": round(r.resume_s * 1e3, 1)}))
    name = "serve_resilience_check" if args.check else "serve_resilience"
    path = write_json(name, rows)
    for n, us, derived in rows:
        print(f"{n},{us:.1f},{derived}")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
