"""Fig. 10 — recomputation-solution comparison on GPT3-175B at
(PP,TP)=(8,8), global batch 32, micro batch 1, seq 16K, SP on.

Paper numbers (storage saving x over baseline*, throughput vs
1F1B+R=100%): Megatron-Kwai operator-aware 1.27x; AdaPipe 1.76x / 1.26x;
Chronos-Pipe+Chronos-Recomp 1.72x / 1.17x; ChronosPipe ALL 2.22x.
*baseline = 1F1B with operator-level recompute only.
"""
from __future__ import annotations

from benchmarks.common import GB, GPT3_175B, memory_model
from repro.core import schedules as S

PP, TP, MB, SEQ = 8, 8, 1, 16384
TOKENS = MB * SEQ
L = GPT3_175B.num_layers


def rows():
    mm = memory_model(GPT3_175B, tp=TP)
    ma = mm.m_a(TOKENS, L)
    state = mm.model_state(L, PP, TP)
    base_act = S.onef1b(PP, 32).peak_activation() * ma

    def tot(frac, off=0.0):
        return frac * ma + mm.model_state(L, PP, TP, offload_frac=off)

    r100 = S.onef1b(PP, 128, recomp=1.0)
    out = {
        "1f1b+oplevel (baseline)": tot(1.0),
        "1f1b+R=100%": tot(0.0),
        "chronos+recomp": tot(S.chronos_recomp(PP, 32).peak_activation(
            count_transient=False)),
        "chronosALL": tot(S.chronos_recomp(PP, 32).peak_activation(
            count_transient=False), off=0.5),
    }
    # throughput proxy: ideal computation fraction (1-bubble-recomp)
    icf = {
        "1f1b+R=100%": r100.ideal_compute_fraction(),
        "chronos+recomp":
            S.chronos_recomp(PP, 128).ideal_compute_fraction(),
    }
    return out, icf


def run(bench):
    out, icf = rows()
    base = out["1f1b+oplevel (baseline)"]
    for k, v in out.items():
        bench.add(f"fig10_{k}_GB", lambda v=v: round(v / GB, 1))
    bench.add("fig10_chronos_recomp_saving_x (paper 1.72x)",
              lambda: round(base / out["chronos+recomp"], 2))
    bench.add("fig10_chronosALL_saving_x (paper 2.22x)",
              lambda: round(base / out["chronosALL"], 2))
    bench.add("fig10_throughput_gain_vs_r100 (paper 1.17x)",
              lambda: round(icf["chronos+recomp"] / icf["1f1b+R=100%"], 2))
    return out
