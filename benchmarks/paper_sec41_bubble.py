"""§4.1 closed-form bubble-overhead check: the constructed schedules'
simulated bubbles vs the paper's formulas at the paper's example point
(Tc = 0.05 T_unit, m = 128, p = 4), plus peak-activation formula checks.
"""
from __future__ import annotations

from repro.core import analysis as AN
from repro.core import schedules as S
from repro.core.schedule import retime_with_comm


def run(bench):
    P, m, tc = 4, 128, 0.05
    bench.add("sec41_formula_chronos_bubble (8.27%)",
              lambda: round(AN.chronos_bubble(P, m, tc), 4))
    bench.add("sec41_formula_1f1b_bubble (5.37%)",
              lambda: round(AN.onef1b_bubble(P, m, tc), 4))
    ch = retime_with_comm(S.chronos(P, m, 2), tc, sync=True)
    f1 = retime_with_comm(S.onef1b(P, m), tc / 2, sync=True)
    bench.add("sec41_simulated_chronos_bubble",
              lambda: round(ch.bubble_ratio(), 4))
    bench.add("sec41_simulated_1f1b_bubble",
              lambda: round(f1.bubble_ratio(), 4))
    for P_ in (4, 8, 16, 32):
        bench.add(
            f"sec41_chronos_peak_P{P_} (formula "
            f"{AN.chronos_peak_frac(P_):.4f})",
            lambda p=P_: round(S.chronos(p, 4 * p, 2).peak_activation(), 4))
    return True
