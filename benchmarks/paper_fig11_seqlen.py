"""Fig. 11 — memory & throughput across sequence lengths on a 32-layer
model at (DP,PP,TP)=(2,4,8), global batch 128, micro batch 2.

Paper: interleave-1F1B OOMs at 8k even with R=50%; at PP4 Chronos-Pipe /
Chronos-Recomp save only 12.5% / 25% of activations vs 1F1B variants;
savings grow with sequence length; Chronos-Pipe throughput -6..9% vs
1F1B; Chronos-Recomp ~ 1F1B+R=50%.

Beyond-paper: the ``repro.seqpipe`` sequence-chunked schedules
(``seq1f1b``, ``chronos_seq`` at 4 chunks) attack the same sweep along
the orthogonal axis — peak activation scales ~1/n_seq with *better*
bubble, so the long-context end of the figure flattens instead of
exploding.
"""
from __future__ import annotations

from benchmarks.common import GB, memory_model
from repro.configs.llama70b_paper import with_layers
from repro.core import schedules as S

DP, PP, TP, MB, L = 2, 4, 8, 2, 32
M = 128 // (MB * DP)
NSQ = 4                             # seq chunks for the seqpipe rows


def rows(seqs=(2048, 4096, 8192, 16384)):
    cfg = with_layers(L)
    mm = memory_model(cfg, tp=TP)
    scheds = {
        "1f1b": S.onef1b(PP, M).peak_activation(),
        "interleave-1f1b": S.interleaved(PP, M, 2).peak_activation(),
        "1f1b+R=50%": S.onef1b(PP, M, recomp=0.5).peak_activation(
            count_transient=False),
        "chronos": S.chronos(PP, M, 2).peak_activation(),
        "chronos+recomp": S.chronos_recomp(PP, M).peak_activation(
            count_transient=False),
        f"seq1f1b(s={NSQ})": S.get_schedule(
            "seq1f1b", PP, M, n_seq=NSQ).peak_activation(),
        f"chronos_seq(s={NSQ})": S.get_schedule(
            "chronos_seq", PP, M, v=2, n_seq=NSQ).peak_activation(),
    }
    out = {}
    for seq in seqs:
        tokens = MB * seq
        state = mm.model_state(L, PP, TP, dp_shard=1)
        out[seq] = {name: (frac * mm.m_a(tokens, L) + state) / GB
                    for name, frac in scheds.items()}
    return out


def run(bench):
    out = rows()
    for seq, row in out.items():
        for name, gbs in row.items():
            bench.add(f"fig11_seq{seq}_{name}_GB",
                      lambda g=gbs: round(g, 1))
    # savings vs 1f1b grow with seq (paper: "increasingly pronounced")
    s2 = 1 - out[2048]["chronos"] / out[2048]["1f1b"]
    s16 = 1 - out[16384]["chronos"] / out[16384]["1f1b"]
    bench.add("fig11_chronos_saving_2k", lambda: round(s2, 3))
    bench.add("fig11_chronos_saving_16k_grows", lambda: round(s16, 3))
    # PP4 activation-only savings: 12.5% (chronos) / 25% (chronos-recomp)
    ch = S.chronos(PP, M, 2).peak_activation()
    # the paper's "25%" Fig-11 statement compares chronos-recomp WITH its
    # recompute transient against 1F1B+R=50% WITHOUT one (0.375 vs 0.5
    # at P=4) — reproduce that accounting here
    cr = S.chronos_recomp(PP, M).peak_activation(count_transient=True)
    f1 = S.onef1b(PP, M).peak_activation()
    r5 = S.onef1b(PP, M, recomp=0.5).peak_activation(count_transient=False)
    bench.add("fig11_act_saving_chronos_vs_1f1b (paper 12.5%)",
              lambda: round(1 - ch / f1, 4))
    bench.add("fig11_act_saving_recomp_vs_r50 (paper 25%)",
              lambda: round(1 - cr / r5, 4))
    # seqpipe: long-context activation ratio vs 1f1b at 16k (>= 1.5x)
    sq = S.get_schedule("seq1f1b", PP, M, n_seq=NSQ).peak_activation()
    bench.add(f"fig11_seq1f1b_s{NSQ}_act_reduction_vs_1f1b",
              lambda: round(f1 / sq, 3))
    return out
