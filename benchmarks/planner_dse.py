"""Design-space planner sweep (`repro.plan`) on the paper's llama70b
testbed: for a ladder of per-device HBM budgets, what (schedule,
recompute depth, offload depth) does the planner pick, and how many
layers does each family train?

Reproduces the Fig. 9(b)/15/16 decision structure from the planner
rather than hand-picked points: recompute-on (chronos_recomp) must beat
1F1B+R=50% in max trainable layers by >= 1.5x at 32 GB, and the picks
shift from plain chronos (roomy budgets) toward recomp+offload (tight
budgets).
"""
from __future__ import annotations

from benchmarks.common import GB, PAPER_ACT_SCALE
from repro.configs.llama70b_paper import with_layers
from repro.plan import PlannerQuery, enumerate_points, plan_under_budget

PP, TP = 8, 8
CFG = with_layers(48)            # the Fig. 9(a) 48-layer testbed


def ladder(hbm_gb: float = 32.0):
    """Family -> (max trainable layers, placement) under the budget
    (paper ladder + the placement column)."""
    q = PlannerQuery(cfg=CFG, pp=PP, tp=TP, hbm_bytes=hbm_gb * GB,
                     reserve=1 * GB, act_scale=PAPER_ACT_SCALE)
    out = {}
    for p in enumerate_points(q):
        out.setdefault(p.describe(), (p.max_layers, p.placement))
    return out


def picks(budgets=(16.0, 24.0, 32.0, 48.0, 64.0)):
    """HBM budget (GB) -> the planner's executable pick summary
    (includes the placement the pick runs under)."""
    out = {}
    for hbm in budgets:
        try:
            ep = plan_under_budget(CFG, pp=PP, tp=TP, hbm_bytes=hbm * GB,
                                   reserve=1 * GB,
                                   act_scale=PAPER_ACT_SCALE)
            out[hbm] = ep.summary()
        except ValueError as e:
            out[hbm] = {"pick": "none-fits", "error": str(e)}
    return out


def run(bench):
    lad = ladder()
    for name in ("1f1b", "1f1b+R=50%", "chronos(v=2)",
                 "chronos_recomp(v=2)+rc=1",
                 "chronos_recomp(v=2)+rc=1+offload=1/2",
                 "v_min(v=2)", "v_half(v=2)", "v_zb(v=2)"):
        bench.add(f"dse_max_layers_{name}",
                  lambda n=name: (lad.get(n) or (None, None))[0])
    bench.add("dse_recomp_on_vs_1f1b_r50 (>=1.5x)",
              lambda: round(lad["chronos_recomp(v=2)+rc=1+offload=1/2"][0]
                            / lad["1f1b+R=50%"][0], 3))
    pk = picks()
    for hbm, s in pk.items():
        bench.add(f"dse_pick_{int(hbm)}GB",
                  lambda s=s: (f"{s['pick']} [{s['placement']}]"
                               if "placement" in s else s["pick"]))
    return lad, pk
