"""Fig. 9 — End-to-end memory & max trainable model size.

(a) 48-layer llama-70b-family model at (PP,TP)=(8,8), global batch 128,
    micro batch 2, seq 4K: per-device activation + model-state memory for
    1F1B / interleaved / Chronos-Pipe / +Chronos-Recomp / +Chronos-Offload.
(b) max trainable layers under 32 GB HBM per schedule:
    paper: 1F1B 40L, Chronos 48L, 1F1B+R50 64L, Chronos-Recomp 80L,
    ChronosPipe-ALL 96L  =>  2.4x vs 1F1B, 1.5x vs 1F1B+R50.
"""
from __future__ import annotations

from benchmarks.common import GB, memory_model
from repro.configs.llama70b_paper import with_layers
from repro.core import schedules as S

PP, TP, MB, SEQ, HBM = 8, 8, 2, 4096, 32 * GB
M = 128 // MB
TOKENS = MB * SEQ


def schedule_points():
    """name -> (act fraction of m_a, offload fraction of layers).

    The v_* rows are the V-shape controllable-memory family (fold-back
    placement, split backward) — no recompute replay and no offload,
    pure placement/scheduling memory control."""
    return {
        "interleave-1f1b": (S.interleaved(PP, 4 * PP, 2).peak_activation(),
                            0.0),
        "1f1b": (S.onef1b(PP, 4 * PP).peak_activation(), 0.0),
        "1f1b+R=50%": (S.onef1b(PP, 4 * PP, recomp=0.5).peak_activation(
            count_transient=False), 0.0),
        "chronos": (S.chronos(PP, 4 * PP, 2).peak_activation(), 0.0),
        "chronos+recomp": (S.chronos_recomp(PP, 4 * PP).peak_activation(
            count_transient=False), 0.0),
        "chronosALL(+offload)": (
            S.chronos_recomp(PP, 4 * PP).peak_activation(
                count_transient=False), 0.5),
        "v_min": (S.get_schedule("v_min", PP, 4 * PP).peak_activation(),
                  0.0),
        "v_half": (S.get_schedule("v_half", PP, 4 * PP).peak_activation(),
                   0.0),
        "v_zb": (S.get_schedule("v_zb", PP, 4 * PP).peak_activation(),
                 0.0),
    }


def fig9a(layers: int = 48):
    cfg = with_layers(layers)
    mm = memory_model(cfg, tp=TP)
    rows = {}
    for name, (frac, off) in schedule_points().items():
        act = frac * mm.m_a(TOKENS, layers)
        state = mm.model_state(layers, PP, TP, offload_frac=off)
        rows[name] = {"act_GB": act / GB, "state_GB": state / GB,
                      "total_GB": (act + state) / GB}
    return rows


def fig9b():
    mm = memory_model(with_layers(8), tp=TP)
    rows = {}
    for name, (frac, off) in schedule_points().items():
        L = 8
        best = 0
        while L <= 512:
            act = frac * mm.m_a(TOKENS, L)
            state = mm.model_state(L, PP, TP, offload_frac=off)
            if act + state + 1.0 * GB <= HBM:
                best = L
                L += 8
            else:
                break
        rows[name] = best
    return rows


def run(bench):
    a = bench.add("fig9a_memory_48L_chronos_total_GB",
                  lambda: round(fig9a()["chronos"]["total_GB"], 2))
    rows = fig9a()
    for k, v in rows.items():
        bench.add(f"fig9a_{k}_act_GB", lambda v=v: round(v["act_GB"], 2))
    b = fig9b()
    for k, v in b.items():
        bench.add(f"fig9b_max_layers_{k}", lambda v=v: v)
    bench.add("fig9b_scale_vs_1f1b (paper 2.4x)",
              lambda: round(b["chronosALL(+offload)"] / b["1f1b"], 2))
    bench.add("fig9b_scale_vs_1f1b_r50 (paper 1.5x)",
              lambda: round(b["chronosALL(+offload)"] / b["1f1b+R=50%"], 2))
    bench.add("fig9b_chronos_vs_1f1b (paper 1.2x)",
              lambda: round(b["chronos"] / b["1f1b"], 2))
    bench.add("fig9b_v_min_vs_1f1b (V family, no recompute tax)",
              lambda: round(b["v_min"] / b["1f1b"], 2))
    return b
