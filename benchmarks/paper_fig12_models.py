"""Fig. 12 — memory across model families at (PP,TP)=(8,8), global batch
128, micro batch 2, seq 4K: Qwen2.5-32B, PaLM-62B, OPT-66B.

Paper: Chronos-Pipe+Chronos-Recomp 1.21-1.26x storage reduction vs
1F1B+R=50% (enables PaLM-62B and OPT-66B in 32 GB); ChronosPipe ALL
1.56-1.58x; vs 1F1B+R=100% ChronosPipe gains ~1.15x throughput and
1.04-1.10x storage.
"""
from __future__ import annotations

from benchmarks.common import (GB, OPT_66B, PALM_62B, QWEN25_32B,
                               memory_model)
from repro.core import schedules as S

PP, TP, MB, SEQ = 8, 8, 2, 4096
M = 128 // MB
TOKENS = MB * SEQ


def rows():
    out = {}
    fr_r50 = S.onef1b(PP, M, recomp=0.5).peak_activation(
        count_transient=False)
    fr_cr = S.chronos_recomp(PP, M).peak_activation(count_transient=False)
    for cfg in (QWEN25_32B, PALM_62B, OPT_66B):
        mm = memory_model(cfg, tp=TP)
        L = cfg.num_layers
        state = mm.model_state(L, PP, TP)
        out[cfg.name] = {
            "1f1b+R=50%": (fr_r50 * mm.m_a(TOKENS, L) + state) / GB,
            "chronos+recomp": (fr_cr * mm.m_a(TOKENS, L) + state) / GB,
            "chronosALL": (fr_cr * mm.m_a(TOKENS, L) + mm.model_state(
                L, PP, TP, offload_frac=0.5)) / GB,
        }
    return out


def run(bench):
    out = rows()
    for name, row in out.items():
        for sched, gbs in row.items():
            bench.add(f"fig12_{name}_{sched}_GB", lambda g=gbs: round(g, 1))
        bench.add(
            f"fig12_{name}_recomp_saving_x (paper 1.21-1.26x)",
            lambda r=row: round(r["1f1b+R=50%"] / r["chronos+recomp"], 2))
        bench.add(
            f"fig12_{name}_ALL_saving_x (paper 1.56-1.58x)",
            lambda r=row: round(r["1f1b+R=50%"] / r["chronosALL"], 2))
        bench.add(
            f"fig12_{name}_fits_32GB_chronosALL",
            lambda r=row: r["chronosALL"] < 32.0)
    return out
