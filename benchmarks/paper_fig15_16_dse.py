"""Fig. 15/16 — design-space exploration.

Fig. 15: Chronos-Recomp at chunk sizes v=2,3,4 under PP4_TP8 with varying
recompute budget: recomputing the *shallowest* layers first always beats
uniform recomputation; e.g. v=4, recompute 25% of layers -> up to 43.75%
activation saving.

Fig. 16: Chronos-Offload with more chunks: diminishing returns (chunk
count equal to PP stops helping).
"""
from __future__ import annotations

from repro.core import schedules as S

PP, M = 4, 32


def fig15():
    out = {}
    for v in (2, 3, 4):
        for rc in range(0, v + 1):
            try:
                if rc == 0:
                    sched = S.chronos(PP, M, v)
                else:
                    sched = S.chronos_recomp(PP, M, v=v, rho=1.0,
                                             recomp_chunks=rc)
                pk = sched.peak_activation(count_transient=False)
            except Exception:
                pk = float("nan")
            out[(v, rc)] = pk
    # uniform-recompute reference at matched budget (recompute fraction
    # rc/v of all layers uniformly in 1F1B)
    for v in (2, 3, 4):
        for rc in range(1, v):
            out[("uniform", v, rc)] = S.onef1b(
                PP, M, recomp=rc / v).peak_activation(count_transient=False)
    return out


def fig16():
    """Usable cooldown bubble growth with chunk count (paper: chunk=3
    gives +50% bubbles at PP4; chunk=4 gives no more than chunk=3)."""
    out = {}
    for v in (2, 3, 4):
        sched = S.chronos(PP, M, v)
        gaps = sched.warmup_cooldown_bubbles(stage=PP - 1)
        out[v] = sum(b - a for a, b in gaps) / (3 * v)  # in T_fwd units
    return out


def run(bench):
    f15 = fig15()
    for k, vfrac in f15.items():
        bench.add(f"fig15_peak_{k}", lambda v=vfrac: round(v, 4))
    # headline: v=4, recompute 1 of 4 chunks (25% of layers)
    want = f15.get((4, 1))
    base = f15.get((4, 0))
    if want == want and base == base:      # not NaN
        bench.add("fig15_v4_25pct_saving (paper up to 43.75%)",
                  lambda: round(1 - want / base, 4))
    # chronos (shallow-first) beats uniform at same budget
    bench.add("fig15_shallow_first_beats_uniform_v2",
              lambda: bool(f15[(2, 1)] < f15[("uniform", 2, 1)]))
    f16 = fig16()
    for v, bub in f16.items():
        bench.add(f"fig16_cooldown_bubbles_v{v}_Tfwd",
                  lambda b=bub: round(b, 3))
    return f15, f16
