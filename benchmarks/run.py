"""Benchmark harness: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

``python benchmarks/run.py --check`` runs the fast tier-1 test suite
instead (slow marker deselected) — the exact invocation scripts/ci.sh
uses, so the bench harness and CI share one entry path.

``python benchmarks/run.py --json-out`` additionally writes one
``BENCH_<module>.json`` per analytic bench module at the repo root
(schema ``{bench, rows, host, commit}``), seeding the repo's perf
record.  The executor micro-benchmark lives in its own entry
(``benchmarks/pipeline_exec.py`` — it must pin the virtual device count
before jax imports) and writes ``BENCH_pipeline_exec.json`` with the
same schema.
"""
import json
import os
import platform
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO)
sys.path.insert(0, os.path.join(REPO, "src"))


def run_tier1(extra_args=()) -> int:
    """Fast tier-1 suite: collect everything, deselect @slow."""
    env = dict(os.environ)
    src = os.path.join(REPO, "src")
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return subprocess.call(
        [sys.executable, "-m", "pytest", "-q", "-m", "not slow",
         *extra_args], env=env, cwd=REPO)


def _host():
    return {"platform": platform.platform(),
            "python": platform.python_version(),
            "cpus": os.cpu_count()}


def _commit():
    try:
        return subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                              capture_output=True, text=True,
                              cwd=REPO).stdout.strip()
    except OSError:
        return "unknown"


def write_json(name: str, rows) -> str:
    """Write one ``BENCH_<name>.json`` perf record (schema:
    ``{bench, rows, host, commit}``)."""
    path = os.path.join(REPO, f"BENCH_{name}.json")
    doc = {"bench": name,
           "rows": [{"name": n, "us_per_call": round(us, 1),
                     "derived": repr(derived)} for n, us, derived in rows],
           "host": _host(), "commit": _commit()}
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def main() -> None:
    if "--check" in sys.argv:
        extra = [a for a in sys.argv[1:] if a != "--check"]
        sys.exit(run_tier1(extra))
    json_out = "--json-out" in sys.argv
    from benchmarks.common import Bench
    from benchmarks import (paper_fig9_memory, paper_fig10_recomp,
                            paper_fig11_seqlen, paper_fig12_models,
                            paper_fig13_p2p, paper_fig14_offload,
                            paper_fig15_16_dse, paper_sec41_bubble,
                            planner_dse, roofline_table, zb_schedules)
    for mod in (paper_sec41_bubble, paper_fig9_memory, paper_fig10_recomp,
                paper_fig11_seqlen, paper_fig12_models, paper_fig13_p2p,
                paper_fig14_offload, paper_fig15_16_dse, planner_dse,
                zb_schedules, roofline_table):
        bench = Bench()
        mod.run(bench)
        bench.emit()
        if json_out:
            name = mod.__name__.rsplit(".", 1)[-1]
            print(f"# wrote {write_json(name, bench.rows)}")


if __name__ == '__main__':
    main()
