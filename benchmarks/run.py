"""Benchmark harness: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows."""
import sys

sys.path.insert(0, "/root/repo")
sys.path.insert(0, "/root/repo/src")


def main() -> None:
    from benchmarks.common import Bench
    from benchmarks import (paper_fig9_memory, paper_fig10_recomp,
                            paper_fig11_seqlen, paper_fig12_models,
                            paper_fig13_p2p, paper_fig14_offload,
                            paper_fig15_16_dse, paper_sec41_bubble,
                            roofline_table)
    bench = Bench()
    for mod in (paper_sec41_bubble, paper_fig9_memory, paper_fig10_recomp,
                paper_fig11_seqlen, paper_fig12_models, paper_fig13_p2p,
                paper_fig14_offload, paper_fig15_16_dse, roofline_table):
        mod.run(bench)
    bench.emit()


if __name__ == '__main__':
    main()
