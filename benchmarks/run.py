"""Benchmark harness: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

``python benchmarks/run.py --check`` runs the fast tier-1 test suite
instead (slow marker deselected) — the exact invocation scripts/ci.sh
uses, so the bench harness and CI share one entry path.
"""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO)
sys.path.insert(0, os.path.join(REPO, "src"))


def run_tier1(extra_args=()) -> int:
    """Fast tier-1 suite: collect everything, deselect @slow."""
    env = dict(os.environ)
    src = os.path.join(REPO, "src")
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return subprocess.call(
        [sys.executable, "-m", "pytest", "-q", "-m", "not slow",
         *extra_args], env=env, cwd=REPO)


def main() -> None:
    if "--check" in sys.argv:
        extra = [a for a in sys.argv[1:] if a != "--check"]
        sys.exit(run_tier1(extra))
    from benchmarks.common import Bench
    from benchmarks import (paper_fig9_memory, paper_fig10_recomp,
                            paper_fig11_seqlen, paper_fig12_models,
                            paper_fig13_p2p, paper_fig14_offload,
                            paper_fig15_16_dse, paper_sec41_bubble,
                            planner_dse, roofline_table, zb_schedules)
    bench = Bench()
    for mod in (paper_sec41_bubble, paper_fig9_memory, paper_fig10_recomp,
                paper_fig11_seqlen, paper_fig12_models, paper_fig13_p2p,
                paper_fig14_offload, paper_fig15_16_dse, planner_dse,
                zb_schedules, roofline_table):
        mod.run(bench)
    bench.emit()


if __name__ == '__main__':
    main()
