"""Elastic-recovery benchmark: phase timings for the fault drill.

Runs the deterministic recovery drill (``repro.ft.elastic_pipeline``
driven by ``repro.ft.inject``) on forced-host devices: an async
checkpoint-writer crash, a device loss at mid-run (detect -> re-plan at
P-1 -> restore the topology-independent checkpoint -> live block
migration -> resume) and a device rejoin (preempt-yield -> warm
scale-up back to P).  Records, per recovery, the five phases the paper's
elastic story prices:

- **detect_s** — fault raise -> driver caught it,
- **replan_s** — mesh re-solve + new StageLayout/schedule build,
- **restore_s** — checkpoint read under the old layout,
- **remap_s** — ``remap_blocks_elastic`` + durable re-save,
- **resume_s** — restart -> first completed step (jit dominates on CPU).

The full run (``P=4``, 12 steps) also replays an uninterrupted baseline
and reports the max per-step loss deviation (measured 0.0: the
migration is bitwise-exact on CPU); it writes ``BENCH_ft_recovery.json``
at the repo root.  ``--check`` is the CI smoke (``P=2``, 6 steps, no
baseline) and writes ``BENCH_ft_recovery_check.json`` so the committed
full record is never clobbered — ``scripts/ci.sh`` runs it every PR.

Must run standalone: the virtual devices require
``XLA_FLAGS=--xla_force_host_platform_device_count`` before jax import.
"""
import argparse
import dataclasses
import os
import sys
import tempfile
import time

ap = argparse.ArgumentParser()
ap.add_argument("--check", action="store_true",
                help="CI smoke: P=2, 6 steps, no baseline replay")
ap.add_argument("--devices", type=int, default=0)
ap.add_argument("--steps", type=int, default=0)
args = ap.parse_args()
P = args.devices or (2 if args.check else 4)
NSTEPS = args.steps or (6 if args.check else 12)

if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={P}"

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO)
sys.path.insert(0, os.path.join(REPO, "src"))

from benchmarks.run import write_json  # noqa: E402
from repro.configs import (OptimizerConfig, ParallelPlan,  # noqa: E402
                           ShapeConfig, TrainConfig, get_reduced)
from repro.ft.elastic_pipeline import train_elastic  # noqa: E402
from repro.ft.inject import (CheckpointCrash, DeviceJoin,  # noqa: E402
                             DeviceLoss)

FAIL_STEP = max(NSTEPS // 2 + 1, 2)
JOIN_STEP = min(FAIL_STEP + 2, NSTEPS - 1)
CKPT_EVERY = 3


def build_tc(ckpt_dir):
    cfg = dataclasses.replace(get_reduced("tinyllama-1.1b"),
                              num_layers=2)
    return TrainConfig(
        model=cfg,
        shape=ShapeConfig("smoke", seq_len=18, global_batch=8,
                          kind="train"),
        plan=ParallelPlan(pp_axis="pp", schedule="chronos", num_chunks=2,
                          microbatch_size=2),
        optimizer=OptimizerConfig(lr=1e-3, warmup_steps=2,
                                  total_steps=NSTEPS,
                                  schedule="constant"),
        log_every=1000, checkpoint_every=CKPT_EVERY,
        checkpoint_dir=ckpt_dir, keep_checkpoints=2)


def main():
    quiet = lambda *_: None  # noqa: E731
    faults = [CheckpointCrash(step=CKPT_EVERY, at="rename"),
              DeviceLoss(step=FAIL_STEP, device=1),
              DeviceJoin(step=JOIN_STEP, device=1)]
    maxerr = None
    with tempfile.TemporaryDirectory() as d_ft:
        t0 = time.perf_counter()
        ft = train_elastic(build_tc(d_ft), n_devices=P, faults=faults,
                           steps=NSTEPS, log=quiet)
        wall = time.perf_counter() - t0
    assert set(ft["loss_by_step"]) == set(range(NSTEPS)), \
        f"not step-count-exact: {sorted(ft['loss_by_step'])}"
    assert [r.kind for r in ft["recoveries"]] == \
        ["device_loss", "scale_up"], ft["recoveries"]
    if not args.check:
        with tempfile.TemporaryDirectory() as d_base:
            base = train_elastic(build_tc(d_base), n_devices=P,
                                 faults=(), steps=NSTEPS, log=quiet)
        maxerr = max(abs(base["loss_by_step"][s] - ft["loss_by_step"][s])
                     for s in range(NSTEPS))
        assert maxerr <= 1e-5, f"diverged from baseline: {maxerr:.3e}"

    rows = []
    for r in ft["recoveries"]:
        tag = f"{r.kind}.P{r.p_from}->P{r.p_to}"
        for phase in ("detect", "replan", "restore", "remap", "resume"):
            rows.append((f"{tag}.{phase}",
                         getattr(r, f"{phase}_s") * 1e6,
                         {"step": r.step}))
    rows.append(("run.total", wall * 1e6,
                 {"P": P, "steps": NSTEPS, "faults": len(faults),
                  "incarnations": len(ft["incarnations"]),
                  "maxerr_vs_baseline": maxerr}))
    name = "ft_recovery_check" if args.check else "ft_recovery"
    path = write_json(name, rows)
    for n, us, derived in rows:
        print(f"{n},{us:.1f},{derived}")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
