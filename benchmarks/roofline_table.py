"""Roofline table from the dry-run artifacts (results/dryrun/*.json).

Single-pod cells only, per the task spec; prints per (arch x shape):
compute / memory / collective terms (seconds), dominant bottleneck,
MODEL_FLOPS / HLO_FLOPs ratio, roofline fraction.
"""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.environ.get("DRYRUN_RESULTS", "/root/repo/results/dryrun")


def load_cells(multi_pod=False):
    """Cells with roofline terms recomputed by the CURRENT analyzer from
    the cached partitioned HLO (see repro.roofline.summarize)."""
    from repro.roofline.summarize import load
    tag = "multipod" if multi_pod else "singlepod"
    return [d for _, d in sorted(load(tag).items())]


def table(multi_pod=False):
    from repro.roofline.summarize import fmt_cell
    rows = []
    for c in load_cells(multi_pod):
        if c["status"] != "ok":
            rows.append((c["arch"], c["shape"], c["status"],
                         c.get("reason", c.get("error", ""))[:60],
                         "", "", "", "", ""))
            continue
        f = fmt_cell(c, multi_pod)
        # fmt_cell: [ok, t_comp, t_mem, t_mem_hloUB, t_coll, dominant,
        #            useful, frac, GB/dev, frac_hloUB]
        rows.append((c["arch"], c["shape"], "ok", f[1], f[2], f[4], f[5],
                     f[6], f[7]))
    return rows


def run(bench):
    rows = table(multi_pod=False)
    ok = sum(1 for r in rows if r[2] == "ok")
    skipped = sum(1 for r in rows if r[2] == "skipped")
    bench.add("roofline_cells_ok", lambda: ok)
    bench.add("roofline_cells_skipped", lambda: skipped)
    for r in rows:
        if r[2] == "ok":
            bench.add(
                f"roofline_{r[0]}_{r[1]}",
                lambda r=r: f"dom={r[6]} frac={r[8]} useful={r[7]}")
    return rows
