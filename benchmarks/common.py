"""Shared infrastructure for the paper-figure benchmarks.

Model configs the paper evaluates against (public literature), the
byte-accounting calibration, and the CSV emission helper.
"""
from __future__ import annotations

import dataclasses
import time

from repro.configs.base import ModelConfig
from repro.core.analysis import MemoryModel

GB = 1e9

# The paper's Fig. 1(a): (70B, 4K) peak activation 35.20 GB/device at
# PP8_TP8, micro-batch 2 => 53.7 KB/token/layer after TP8.  Our
# Megatron-selective estimator gives ~23 KB (flash + op-level recompute +
# sequence parallelism); the paper's motivation table evidently accounts
# full storage without SP.  PAPER_ACT_SCALE aligns our estimator with
# their accounting for the reproduction figures; "ours" rows use the
# uncalibrated estimator.
PAPER_ACT_SCALE = 53.7 / 23.0

GPT3_175B = ModelConfig(
    name="gpt3-175b", family="dense", num_layers=96, d_model=12288,
    num_heads=96, num_kv_heads=96, d_ff=49152, vocab_size=50257,
    act="gelu")

QWEN25_32B = ModelConfig(
    name="qwen2.5-32b", family="dense", num_layers=64, d_model=5120,
    num_heads=40, num_kv_heads=8, d_ff=27648, vocab_size=152064,
    qkv_bias=True, act="silu")

PALM_62B = ModelConfig(
    name="palm-62b", family="dense", num_layers=64, d_model=8192,
    num_heads=32, num_kv_heads=1, d_ff=32768, vocab_size=256000,
    act="silu")

OPT_66B = ModelConfig(
    name="opt-66b", family="dense", num_layers=64, d_model=9216,
    num_heads=72, num_kv_heads=72, d_ff=36864, vocab_size=50272,
    act="gelu")


def memory_model(cfg: ModelConfig, tp: int, calibrated: bool = True
                 ) -> MemoryModel:
    mm = MemoryModel.build(cfg, tp=tp)
    if calibrated:
        mm = dataclasses.replace(
            mm, act_per_token_layer=mm.act_per_token_layer
            * PAPER_ACT_SCALE)
    return mm


class Bench:
    """Collects (name, us_per_call, derived) rows."""

    def __init__(self):
        self.rows = []

    def add(self, name: str, fn):
        t0 = time.perf_counter()
        derived = fn()
        us = (time.perf_counter() - t0) * 1e6
        self.rows.append((name, us, derived))
        return derived

    def emit(self):
        for name, us, derived in self.rows:
            print(f"{name},{us:.0f},{derived}")
